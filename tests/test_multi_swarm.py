"""Batched multi-swarm engine tests (repro.core.multi_swarm + the batched
fused Pallas kernel + the request-batching front end).

The load-bearing invariant: batching is a *scheduling* transform, never a
semantic one — row s of any batch is bit-identical to the corresponding
standalone single-swarm computation (same seed, same variant, same
block size). Asserted with exact array equality, not allclose.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (PSOConfig, batch_row, best_of_batch, init_batch,
                        init_swarm, run_many, solve, solve_many)
from repro.core.tuner import PSOTuner, PSO_COEFF_DIMS, make_solve_many_fitness
from repro.kernels import ops

# >= 8 heterogeneous seeds (acceptance criterion), spread over the u32 range
SEEDS = [0, 1, 7, 42, 99, 123, 100000, 2 ** 31 - 5]


@pytest.mark.parametrize("variant", ["reduction", "queue", "queue_lock"])
def test_solve_many_rows_bit_identical_to_solve(variant):
    cfg = PSOConfig(dim=3, particle_cnt=64, fitness="rastrigin")
    b = solve_many(cfg, SEEDS, iters=25, variant=variant)
    for i, sd in enumerate(SEEDS):
        s = solve(cfg, seed=sd, iters=25, variant=variant)
        # exact: same RNG counters, same arithmetic, vmap only reschedules
        assert np.asarray(b.gbest_fit)[i] == np.asarray(s.gbest_fit)
        np.testing.assert_array_equal(np.asarray(b.pos[i]),
                                      np.asarray(s.pos))
        np.testing.assert_array_equal(np.asarray(b.pbest_fit[i]),
                                      np.asarray(s.pbest_fit))
        np.testing.assert_array_equal(np.asarray(b.gbest_pos[i]),
                                      np.asarray(s.gbest_pos))
    assert int(b.iteration[0]) == 25


def test_batched_fused_kernel_bit_identical_to_single():
    """Kernel path: batched pallas_call row s == standalone fused call."""
    cfg = PSOConfig(dim=7, particle_cnt=256, fitness="cubic")
    b = init_batch(cfg, SEEDS[:4])
    out = ops.run_queue_lock_fused_batch(cfg, b, iters=4, block_n=128)
    for s in range(4):
        single = ops.run_queue_lock_fused(cfg, batch_row(b, s), iters=4,
                                          block_n=128)
        np.testing.assert_array_equal(np.asarray(out.pos[s]),
                                      np.asarray(single.pos))
        np.testing.assert_array_equal(np.asarray(out.gbest_fit)[s],
                                      np.asarray(single.gbest_fit))
        np.testing.assert_array_equal(np.asarray(out.gbest_pos[s]),
                                      np.asarray(single.gbest_pos))
        np.testing.assert_array_equal(np.asarray(out.pbest_fit[s]),
                                      np.asarray(single.pbest_fit))


def test_batched_fused_kernel_matches_vmapped_jnp_path():
    """Single-block regime: the kernel's in-iteration gbest freshness
    coincides with synchronous queue-lock, so the batched kernel and the
    vmapped jnp path must agree swarm-for-swarm."""
    cfg = PSOConfig(dim=2, particle_cnt=128, fitness="cubic")
    b = init_batch(cfg, SEEDS[:4])
    k = ops.run_queue_lock_fused_batch(cfg, b, iters=5, block_n=128)
    j = run_many(cfg, b, 5, "queue_lock")
    np.testing.assert_allclose(np.asarray(k.gbest_fit),
                               np.asarray(j.gbest_fit), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(k.pos), np.asarray(j.pos),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("fitness,dim,ok", [
    ("cubic", 1, lambda gf: np.all(np.abs(gf - 900000.0) < 900.0)),
    ("rastrigin", 3, lambda gf: np.all(gf > -5.0)),   # optimum 0
])
def test_mixed_seed_batch_converges(fitness, dim, ok):
    cfg = PSOConfig(dim=dim, particle_cnt=128, fitness=fitness, w=0.7)
    b = solve_many(cfg, SEEDS, iters=150, variant="queue")
    gf = np.asarray(b.gbest_fit)
    assert gf.shape == (len(SEEDS),)
    assert ok(gf), gf


def test_per_swarm_coeffs():
    """Uniform coeffs == the config's own floats reproduce the default path;
    heterogeneous coeffs actually change per-swarm trajectories."""
    cfg = PSOConfig(dim=4, particle_cnt=64, fitness="sphere").resolved()
    s_cnt = 4
    seeds = SEEDS[:s_cnt]
    uniform = (jnp.full(s_cnt, cfg.w), jnp.full(s_cnt, cfg.c1),
               jnp.full(s_cnt, cfg.c2))
    a = solve_many(cfg, seeds, iters=10, coeffs=uniform)
    bb = solve_many(cfg, seeds, iters=10)
    # allclose, not exact: traced coeffs vs trace-time-folded floats are
    # different compiled programs (only seed-batching is exact-by-contract),
    # and ulp-level differences compound over the 10 chaotic iterations
    np.testing.assert_allclose(np.asarray(a.pos), np.asarray(bb.pos),
                               rtol=2e-3, atol=2e-3)
    hetero = (jnp.asarray([0.3, 0.5, 0.7, 0.9]), uniform[1], uniform[2])
    c = solve_many(cfg, seeds, iters=10, coeffs=hetero)
    assert not np.array_equal(np.asarray(c.pos), np.asarray(a.pos))


def test_best_of_batch():
    cfg = PSOConfig(dim=1, particle_cnt=64)
    b = solve_many(cfg, SEEDS, iters=50)
    fit, pos, idx = best_of_batch(b)
    gf = np.asarray(b.gbest_fit)
    assert float(fit) == gf.max()
    np.testing.assert_array_equal(np.asarray(pos),
                                  np.asarray(b.gbest_pos[int(idx)]))


def test_tuner_batched_evaluation_on_solve_many():
    """PSOTuner with make_solve_many_fitness: the whole population x probe
    grid runs as one batched device program per tuner iteration."""
    cfg = PSOConfig(dim=5, particle_cnt=64, fitness="rastrigin")
    bf = make_solve_many_fitness(cfg, seeds=[0, 1], iters=25)
    tuner = PSOTuner(PSO_COEFF_DIMS, particles=6, seed=0)
    res = tuner.run(batch_fitness=bf, iters=2)
    assert res.evaluations == 6 * 2
    assert np.isfinite(res.best_fitness)
    assert set(res.best_params) == {"w", "c1", "c2"}
    # batched scores must match scoring one candidate alone (row identity)
    one = bf([res.best_params])
    np.testing.assert_allclose(one[0], res.best_fitness, rtol=1e-6)


def test_tuner_rejects_ambiguous_fitness_args():
    tuner = PSOTuner(PSO_COEFF_DIMS, particles=4)
    with pytest.raises(ValueError):
        tuner.run()
    with pytest.raises(ValueError):
        tuner.run(lambda p: 0.0, batch_fitness=lambda pop: [0.0] * len(pop))


def test_solve_server_batches_and_matches_direct_solve():
    from repro.launch.serve import SolveRequest, SolveServer
    reqs = [SolveRequest(dim=1, particle_cnt=64, fitness="cubic",
                         seed=i, iters=30) for i in range(5)]
    reqs += [SolveRequest(dim=3, particle_cnt=64, fitness="rastrigin",
                          seed=i, iters=30) for i in range(3)]
    srv = SolveServer(max_batch=16)
    results = srv.solve_all(reqs)
    assert len(results) == 8
    # two compilation groups -> two dispatches; 5 requests pad to bucket 8,
    # 3 requests to the restored minimum bucket 4
    assert srv.stats.dispatches == 2
    assert srv.stats.padded_rows == (8 - 5) + (4 - 3)
    for r in results:
        direct = solve(r.request.config(), seed=r.request.seed,
                       iters=r.request.iters, variant=r.request.variant)
        assert r.gbest_fit == float(direct.gbest_fit)   # bit-identical
        np.testing.assert_array_equal(r.gbest_pos,
                                      np.asarray(direct.gbest_pos))


def test_solve_server_rejects_sub_bucket_max_batch():
    from repro.launch.serve import SolveServer
    SolveServer(max_batch=4)       # bucket 4 is legal again (engine pin)
    with pytest.raises(ValueError):
        SolveServer(max_batch=2)   # below the smallest bucket
    with pytest.raises(ValueError):
        SolveServer(backend="bogus")


def test_bucket4_row_identity_regression():
    """Regression for the S=4 serving anomaly (PR 1): the exact offending
    shape (dim=3, n=64, sphere) whose S=4 fori_loop program FMA-contracted
    the velocity chain 1 ulp off the standalone program on XLA:CPU. With
    the engine-level pin (run_many pads sub-MIN_VALIDATED_SWARMS batches
    to the validated shape), a bucket-4 dispatch is row-bit-identical to
    the standalone solve again."""
    from repro.core import MIN_VALIDATED_SWARMS
    from repro.launch.serve import SolveRequest, SolveServer
    assert MIN_VALIDATED_SWARMS == 8
    # engine level: the raw S=4 batch on the offending shape
    cfg = PSOConfig(dim=3, particle_cnt=64, fitness="sphere")
    seeds = [0, 1, 2, 3]
    b = solve_many(cfg, seeds, iters=100, variant="queue")
    assert b.swarm_cnt == 4        # dead rows are sliced off
    for i, sd in enumerate(seeds):
        s = solve(cfg, seed=sd, iters=100, variant="queue")
        np.testing.assert_array_equal(np.asarray(b.pos[i]),
                                      np.asarray(s.pos))
        np.testing.assert_array_equal(np.asarray(b.gbest_fit)[i],
                                      np.asarray(s.gbest_fit))
    # serving level: a 4-request flush rides bucket 4 and stays identical
    reqs = [SolveRequest(dim=3, particle_cnt=64, fitness="sphere", seed=i,
                         iters=100, variant="queue") for i in seeds]
    srv = SolveServer(max_batch=64)
    for r in srv.solve_all(reqs):
        direct = solve(PSOConfig(dim=3, particle_cnt=64, fitness="sphere"),
                       seed=r.request.seed, iters=100, variant="queue")
        assert r.batch_size == 4
        assert r.gbest_fit == float(direct.gbest_fit)
        np.testing.assert_array_equal(r.gbest_pos,
                                      np.asarray(direct.gbest_pos))


def test_solve_server_kernel_backend():
    from repro.launch.serve import SolveRequest, SolveServer
    reqs = [SolveRequest(dim=2, particle_cnt=128, fitness="cubic", seed=i,
                         iters=4, variant="queue_lock") for i in range(3)]
    srv = SolveServer(max_batch=8, backend="kernel", block_n=128)
    results = srv.solve_all(reqs)
    for r in results:
        cfg = r.request.config().resolved()
        direct = ops.run_queue_lock_fused(
            cfg, init_swarm(cfg, r.request.seed), iters=4, block_n=128)
        assert r.gbest_fit == float(direct.gbest_fit)
