"""Schedule autotuner (repro.core.autotune) + its facade/serving wiring:
candidate enumeration, model ranking, measured fallback with the
never-worse fixed anchor, the on-disk cache (hit on second resolve, no
re-measurement), Method(schedule=...) semantics, and the serving-layer
model-only entry points."""
import json
import os

import pytest

import repro
from repro.core import autotune as at
from repro.core.autotune import (AutotuneCache, Schedule,
                                 candidate_schedules, fixed_schedule,
                                 rank_schedules, resolve_schedule,
                                 shape_key)


@pytest.fixture
def cache(tmp_path):
    return AutotuneCache(str(tmp_path / "autotune.json"))


# --------------------------------------------------------------------------
# Shape keys and candidate enumeration.
# --------------------------------------------------------------------------

def test_shape_key_buckets_iters():
    a = shape_key("sphere", 4, 256, 50, "float32")
    b = shape_key("sphere", 4, 256, 64, "float32")
    c = shape_key("sphere", 4, 256, 65, "float32")
    assert a == b != c          # 50 and 64 share the pow2 bucket; 65 doesn't


def test_shape_key_distinguishes_custom_and_constrained():
    import jax.numpy as jnp
    from repro.core.problem import Problem
    custom = Problem(name="my_bowl", sense="min",
                     fn=lambda x: jnp.sum(x ** 2, axis=-1))
    assert "custom:" in shape_key(custom, 4, 256, 64, "float32")
    assert shape_key("sphere", 4, 256, 64, "float32") != \
        shape_key("sphere_simplex", 4, 256, 64, "float32")


def test_candidates_no_kernel_without_tpu():
    cands = candidate_schedules(4, 256, 64, kernel_ok=False)
    assert cands and all(s.backend == "jnp" for s in cands)
    variants = {s.variant for s in cands}
    assert variants == {"reduction", "queue", "queue_lock", "async"}
    # async fans out over block sizes x sync intervals
    assert sum(s.variant == "async" for s in cands) > 1


def test_candidates_kernel_on_tpu_and_budget():
    cands = candidate_schedules(4, 256, 64, kernel_ok=True,
                                max_candidates=24)
    assert any(s.backend == "kernel" for s in cands)
    assert len(cands) <= 24
    # thinning keeps the non-async variants intact
    assert {s.variant for s in cands if s.variant != "async"} == \
        {"reduction", "queue", "queue_lock"}


def test_candidate_block_choices_divide():
    for s in candidate_schedules(8, 384, 64, kernel_ok=True):
        if s.block_n is not None:
            assert 384 % s.block_n == 0


# --------------------------------------------------------------------------
# Ranking and resolution.
# --------------------------------------------------------------------------

def test_rank_orders_by_predicted_us_and_drops_invalid():
    cands = [Schedule("queue", "jnp"), Schedule("async", "jnp",
                                                block_n=100, sync_every=8),
             Schedule("async", "jnp", block_n=64, sync_every=8)]
    ranked = rank_schedules(cands, "sphere", 4, 256, 64)
    # block_n=100 does not divide 256: dropped
    assert all(s.block_n != 100 for s in ranked)
    assert len(ranked) == 2
    assert all(s.source == "model" and s.predicted_us is not None
               for s in ranked)
    us = [s.predicted_us for s in ranked]
    assert us == sorted(us)


def test_resolve_model_only_no_measurement(cache, monkeypatch):
    def boom(*a, **k):
        raise AssertionError("measure_schedule called under measure=False")
    monkeypatch.setattr(at, "measure_schedule", boom)
    s = resolve_schedule("sphere", 4, 256, 64, measure=False, cache=cache,
                         kernel_ok=False)
    assert s.source == "model" and s.backend == "jnp"


def test_resolve_measured_includes_fixed_anchor(cache, monkeypatch):
    """The fixed default must be among the timed candidates even when the
    model ranks it outside the top-K — the never-worse guarantee."""
    measured = []

    def fake_measure(sched, *a, **k):
        measured.append(sched)
        return 100.0 if sched.variant != "queue" else 1.0
    monkeypatch.setattr(at, "measure_schedule", fake_measure)
    # force a ranking where queue cannot be in the top-K
    monkeypatch.setattr(at, "rank_schedules", lambda cands, *a, **k: [
        Schedule("async", "jnp", block_n=64, sync_every=k_, source="model",
                 predicted_us=float(k_)) for k_ in (1, 2, 4, 8)])
    s = resolve_schedule("sphere", 4, 256, 64, cache=cache, kernel_ok=False,
                         top_k=3)
    assert any(m.variant == "queue" for m in measured)
    assert s.variant == "queue" and s.source == "measured"
    assert s.measured_us == 1.0


def test_resolve_noise_margin_keeps_fixed_default(cache, monkeypatch):
    """A challenger within MEASURE_NOISE_MARGIN of the fixed default must
    lose to it — within-noise wins flip sign on re-measurement."""
    def fake_measure(sched, *a, **k):
        # challenger "wins" by 5% — inside the 10% noise margin
        return 95.0 if sched.variant == "async" else 100.0
    monkeypatch.setattr(at, "measure_schedule", fake_measure)
    monkeypatch.setattr(at, "rank_schedules", lambda cands, *a, **k: [
        Schedule("async", "jnp", block_n=64, sync_every=8, source="model",
                 predicted_us=1.0)])
    s = resolve_schedule("sphere", 4, 256, 64, cache=cache, kernel_ok=False)
    assert s.variant == "queue"             # the fixed default held

    def clear_win(sched, *a, **k):
        return 50.0 if sched.variant == "async" else 100.0
    monkeypatch.setattr(at, "measure_schedule", clear_win)
    s2 = resolve_schedule("sphere", 4, 512, 64, cache=cache,
                          kernel_ok=False)
    assert s2.variant == "async"            # a 2x win displaces it


def test_resolve_cache_hit_skips_measurement(cache, monkeypatch):
    calls = {"n": 0}
    real = at.measure_schedule

    def counting(*a, **k):
        calls["n"] += 1
        return real(*a, **k)
    monkeypatch.setattr(at, "measure_schedule", counting)
    first = resolve_schedule("sphere", 4, 128, 16, cache=cache,
                             kernel_ok=False, top_k=1)
    n_first = calls["n"]
    assert n_first >= 1 and first.source == "measured"
    second = resolve_schedule("sphere", 4, 128, 16, cache=cache,
                              kernel_ok=False, top_k=1)
    assert calls["n"] == n_first            # no re-measurement
    assert second.source == "cache"
    assert (second.variant, second.backend, second.block_n) == \
        (first.variant, first.backend, first.block_n)


def test_cache_survives_process_restart(cache, tmp_path):
    cache.put("jnp", "k1", Schedule("async", "jnp", block_n=64,
                                    sync_every=16, measured_us=3.0))
    fresh = AutotuneCache(cache.path)        # same disk, new LRU
    hit = fresh.get("jnp", "k1")
    assert hit is not None and hit.source == "cache"
    assert (hit.variant, hit.block_n, hit.sync_every) == ("async", 64, 16)
    assert fresh.get("kernel", "k1") is None     # scope separates


def test_cache_tolerates_corrupt_file(tmp_path):
    p = tmp_path / "autotune.json"
    p.write_text("{not json")
    c = AutotuneCache(str(p))
    assert c.get("jnp", "k") is None
    c.put("jnp", "k", Schedule("queue", "jnp"))
    assert json.load(open(p))                    # rewritten valid


def test_measure_schedule_smoke():
    t = at.measure_schedule(Schedule("queue", "jnp"), "sphere", 2, 64,
                            iters=4, repeats=1)
    assert 0 < t < 1e6


# --------------------------------------------------------------------------
# Facade wiring: Method(schedule=...).
# --------------------------------------------------------------------------

def test_method_schedule_validation():
    assert repro.Method().schedule == "fixed"
    repro.Method(schedule="auto")
    with pytest.raises(ValueError, match="schedule"):
        repro.Method(schedule="bogus")
    with pytest.raises(ValueError, match="island"):
        repro.Method(schedule="auto", islands=2)


def test_method_fixed_schedule_matches_legacy_rule():
    s = repro.Method(variant="queue").resolve_schedule("sphere", 4, 128, 8)
    assert (s.variant, s.backend, s.source) == ("queue", "jnp", "fixed")


def test_method_auto_schedule_resolves_and_solves(tmp_path, monkeypatch):
    monkeypatch.setenv(at.CACHE_ENV, str(tmp_path / "cache.json"))
    m = repro.Method(schedule="auto")
    s = m.resolve_schedule("sphere", 4, 128, 16, measure=False)
    assert s.source in ("model", "cache")
    r = repro.solve("sphere", dim=4, particles=128, iters=16, seed=0,
                    schedule="auto")
    import numpy as np
    assert np.isfinite(r.best_fit)
    # fixed-schedule solves still work with the feature present
    rf = repro.solve("sphere", dim=4, particles=128, iters=16, seed=0)
    assert np.isfinite(rf.best_fit)


def test_record_history_restricts_auto_to_jnp(tmp_path, monkeypatch):
    monkeypatch.setenv(at.CACHE_ENV, str(tmp_path / "cache.json"))
    m = repro.Method(schedule="auto", record_history=True)
    s = m.resolve_schedule("sphere", 4, 128, 16, measure=False)
    assert s.backend == "jnp"


def test_auto_history_no_longer_forces_jnp():
    # record_history used to force backend="auto" to jnp (with a one-time
    # warning); the kernel backend now records history by chunking its
    # launch at sync points, so auto resolves by the plain device rule and
    # never warns.
    m = repro.Method(backend="auto", variant="queue_lock",
                     record_history=True)
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert m.resolve_backend() in ("jnp", "kernel")
    # the explicit kernel pin is accepted now, too
    mk = repro.Method(backend="kernel", variant="queue_lock",
                      record_history=True)
    assert mk.resolve_backend() == "kernel"


# --------------------------------------------------------------------------
# Serving-layer entry points (model-only).
# --------------------------------------------------------------------------

def test_tuned_sync_every_is_valid(cache):
    k = at.tuned_sync_every("sphere", 4, 256, 64, cache=cache)
    assert k in at.SYNC_EVERY_CHOICES


def test_bucket_ladder_shape():
    ladder = at.bucket_ladder("sphere", 4, 128, 32, max_batch=64)
    assert ladder[0] == 4
    assert list(ladder) == sorted(set(ladder))
    assert all(b <= 64 for b in ladder)
    assert all(ladder[i + 1] == 2 * ladder[i]
               for i in range(len(ladder) - 1))


def test_serve_autotune_rewrites_async_sync_every(tmp_path, monkeypatch):
    monkeypatch.setenv(at.CACHE_ENV, str(tmp_path / "cache.json"))
    from repro.launch.serve import SolveRequest, SolveServer
    srv = SolveServer(max_batch=4, autotune=True)
    r = SolveRequest(fitness="sphere", dim=4, particle_cnt=64, iters=16,
                     seed=0, variant="async")
    tuned = srv._tuned_request(r)
    assert tuned.sync_every in at.SYNC_EVERY_CHOICES
    # non-async requests pass through untouched
    rq = SolveRequest(fitness="sphere", dim=4, particle_cnt=64, iters=16,
                      seed=0, variant="queue")
    assert srv._tuned_request(rq) is rq


def test_serve_autotune_end_to_end(tmp_path, monkeypatch):
    monkeypatch.setenv(at.CACHE_ENV, str(tmp_path / "cache.json"))
    from repro.launch.serve import SolveRequest, SolveServer
    plain = SolveServer(max_batch=4)
    tuned = SolveServer(max_batch=4, autotune=True)
    reqs = [SolveRequest(fitness="sphere", dim=4, particle_cnt=64,
                         iters=16, seed=s, variant="queue")
            for s in range(3)]
    a = plain.solve_all(reqs)
    b = tuned.solve_all(reqs)
    for x, y in zip(a, b):
        assert x.gbest_fit == y.gbest_fit   # sync variants: no change
