"""arctic-480b — 128-expert top-2 MoE with a parallel dense-residual FFN.
[hf:Snowflake/snowflake-arctic-base; hf]

Adafactor optimizer: 480B params × fp32 Adam does not fit 16 GB/chip on a
single pod; factored second moment + bf16 momentum does (DESIGN.md §6).
"""
from .base import ArchConfig, register

ARCTIC_480B = register(ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab=32000,
    moe=True, n_experts=128, top_k=2,
    dense_residual=True, dense_residual_ff=4864,
    optimizer="adafactor",
    source="hf:Snowflake/snowflake-arctic-base",
))
